"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) blocks.

The chunked SSD algorithm is GEMM-dominated with N = dstate (64/128) — the
paper's small-N regime — so its inner contractions are exactly the irregular
shapes ftIMM targets (noted in DESIGN.md §3).  Layout follows the reference:
d_inner = 2*d_model, headdim P = 64, n_groups = 1, conv width 4, scalar decay
A per head.

Train/prefill: chunked scan (chunk Q=256) — intra-chunk dense masked GEMMs +
inter-chunk state recurrence via lax.scan.
Decode: O(1) recurrent update of (h, conv_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.dist import current_dist, shard_act
from .layers import dense, rms_norm

CONV_WIDTH = 4
HEADDIM = 64


def ssm_dims(d_model: int, ssm_state: int):
    d_inner = 2 * d_model
    nheads = d_inner // HEADDIM
    return d_inner, nheads, ssm_state


def init_ssm_params(key, d_model: int, ssm_state: int, dtype=jnp.float32) -> dict:
    d_inner, nheads, n = ssm_dims(d_model, ssm_state)
    conv_ch = d_inner + 2 * n
    ks = jax.random.split(key, 4)
    s_in = (2.0 / d_model) ** 0.5
    proj_out = 2 * d_inner + 2 * n + nheads
    return {
        "in_proj": jax.random.normal(ks[0], (d_model, proj_out), dtype) * s_in,
        "conv_w": jax.random.normal(ks[1], (CONV_WIDTH, conv_ch), dtype) * 0.5,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads).astype(dtype)),
        "D_skip": jnp.ones((nheads,), dtype),
        "dt_bias": jnp.full((nheads,), -2.0, dtype),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": jax.random.normal(ks[3], (d_inner, d_model), dtype)
                    * (2.0 / d_inner) ** 0.5,
    }


def _split_proj(zxbcdt, d_inner: int, n: int, nheads: int):
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    b = zxbcdt[..., 2 * d_inner:2 * d_inner + n]
    c = zxbcdt[..., 2 * d_inner + n:2 * d_inner + 2 * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * n:]
    return z, x, b, c, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with taps w:(W, C)."""
    out = jnp.zeros_like(x)
    for i in range(CONV_WIDTH):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :x.shape[1]]
        out = out + shifted * w[CONV_WIDTH - 1 - i]
    return jax.nn.silu(out + b)


def ssd_forward(
    x: jax.Array,              # (B, S, D_model)
    params: dict,
    *,
    ssm_state: int,
    chunk: int = 256,
    compute_dtype=jnp.bfloat16,
    initial_state: jax.Array | None = None,
    unroll: bool = False,
):
    """Chunked SSD scan. Returns (y (B,S,D), final_state (B,H,P,N))."""
    bsz, s, d_model = x.shape
    d_inner, nheads, n = ssm_dims(d_model, ssm_state)
    p = HEADDIM

    zxbcdt = dense(x, params["in_proj"], compute_dtype)
    z, xs, b, c, dt = _split_proj(zxbcdt, d_inner, n, nheads)
    xbc = _causal_conv(jnp.concatenate([xs, b, c], axis=-1),
                       params["conv_w"].astype(compute_dtype),
                       params["conv_b"].astype(compute_dtype))
    xs = xbc[..., :d_inner].reshape(bsz, s, nheads, p)
    b = xbc[..., d_inner:d_inner + n]                     # (B,S,N) groups=1
    c = xbc[..., d_inner + n:]

    a = -jnp.exp(params["A_log"].astype(jnp.float32))     # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,H)

    pad = (-s) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = xs.shape[1] // chunk
    q = chunk

    ctx = current_dist()
    if ctx is not None and ctx.ssm_head_shard:
        # shard the SSD head dim over the model axis: the (B, Q, Q, H)
        # intra-chunk decay/score tensors shrink by the TP degree
        xs = shard_act(xs, "dp", None, "model", None)
        dt = shard_act(dt, "dp", None, "model")

    # chunk-major: (nc, B, Q, ...)
    xs_c = xs.reshape(bsz, nc, q, nheads, p).swapaxes(0, 1)
    b_c = b.reshape(bsz, nc, q, n).swapaxes(0, 1)
    c_c = c.reshape(bsz, nc, q, n).swapaxes(0, 1)
    dt_c = dt.reshape(bsz, nc, q, nheads).swapaxes(0, 1)

    h0 = (initial_state if initial_state is not None
          else jnp.zeros((bsz, nheads, p, n), jnp.float32))

    def chunk_step(h, xs_):
        x_q, b_q, c_q, dt_q = xs_
        x_f = x_q.astype(jnp.float32)
        b_f = b_q.astype(jnp.float32)
        c_f = c_q.astype(jnp.float32)
        da = dt_q * a                                    # (B,Q,H) log-decay
        lcum = jnp.cumsum(da, axis=1)                    # (B,Q,H)
        # intra-chunk: M[i,j] = exp(L_i - L_j) for j <= i.  Mask BEFORE the
        # exp: entries with j > i have positive diff and would overflow to
        # inf — fine in forward (where -> 0) but the VJP of where still
        # propagates inf * 0 = nan into the dt/A_log gradients.
        diff = lcum[:, :, None, :] - lcum[:, None, :, :]  # (B,Q,Q,H)
        causal = jnp.tril(jnp.ones((q, q), bool))
        diff = jnp.where(causal[None, :, :, None], diff, -1e30)
        m = jnp.exp(diff)
        cb = jnp.einsum("bin,bjn->bij", c_f, b_f)         # (B,Q,Q)
        xdt = x_f * dt_q[..., None]                       # (B,Q,H,P)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp",
                             cb, m, xdt)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bhpn,bih->bihp",
                             c_f, h, jnp.exp(lcum))
        # state update: h' = exp(sum da) h + sum_j exp(L_Q - L_j) xdt_j b_j
        decay_tot = jnp.exp(lcum[:, -1, :])               # (B,H)
        w = jnp.exp(lcum[:, -1:, :] - lcum)               # (B,Q,H)
        h_new = (decay_tot[:, :, None, None] * h
                 + jnp.einsum("bjh,bjhp,bjn->bhpn", w, xdt, b_f))
        return h_new, (y_intra + y_inter)

    # Recompute the (B, Q, Q, H) decay/score intermediates in backward
    # instead of saving them per chunk step.
    h_final, y_c = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                                (xs_c, b_c, c_c, dt_c),
                                unroll=True if unroll else 1)
    y = y_c.swapaxes(0, 1).reshape(bsz, nc * q, nheads, p)[:, :s]
    y = y + xs[:, :s] * params["D_skip"].astype(compute_dtype)[None, None, :, None]
    y = y.reshape(bsz, s, d_inner).astype(compute_dtype)
    y = y * jax.nn.silu(z[:, :s])
    y = rms_norm(y, params["norm"])
    return dense(y, params["out_proj"], compute_dtype), h_final


def ssd_decode_step(
    x: jax.Array,              # (B, 1, D_model)
    params: dict,
    state: dict,               # {"h": (B,H,P,N) f32, "conv": (B,W-1,C)}
    *,
    ssm_state: int,
    compute_dtype=jnp.bfloat16,
):
    """O(1) recurrent decode. Returns (y (B,1,D), new_state)."""
    bsz, _, d_model = x.shape
    d_inner, nheads, n = ssm_dims(d_model, ssm_state)
    p = HEADDIM

    zxbcdt = dense(x[:, 0], params["in_proj"], compute_dtype)
    z, xs, b, c, dt = _split_proj(zxbcdt, d_inner, n, nheads)
    xbc = jnp.concatenate([xs, b, c], axis=-1)            # (B, C)

    conv = state["conv"]                                   # (B, W-1, C)
    w = params["conv_w"].astype(compute_dtype)
    window = jnp.concatenate([conv, xbc[:, None, :]], axis=1)  # (B, W, C)
    xbc_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, w)
        + params["conv_b"].astype(compute_dtype))
    new_conv = window[:, 1:]

    xs = xbc_out[:, :d_inner].reshape(bsz, nheads, p)
    b = xbc_out[:, d_inner:d_inner + n].astype(jnp.float32)
    c = xbc_out[:, d_inner + n:].astype(jnp.float32)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)

    h = state["h"]
    decay = jnp.exp(dt * a)                                # (B,H)
    xdt = xs.astype(jnp.float32) * dt[..., None]           # (B,H,P)
    h_new = decay[:, :, None, None] * h + jnp.einsum("bhp,bn->bhpn", xdt, b)
    y = jnp.einsum("bhpn,bn->bhp", h_new, c)
    y = y + xs.astype(jnp.float32) * params["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, d_inner).astype(compute_dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"])
    out = dense(y, params["out_proj"], compute_dtype)
    return out[:, None, :], {"h": h_new, "conv": new_conv}


def init_ssm_state(bsz: int, d_model: int, ssm_state: int,
                   dtype=jnp.bfloat16) -> dict:
    d_inner, nheads, n = ssm_dims(d_model, ssm_state)
    conv_ch = d_inner + 2 * n
    return {
        "h": jnp.zeros((bsz, nheads, HEADDIM, n), jnp.float32),
        "conv": jnp.zeros((bsz, CONV_WIDTH - 1, conv_ch), dtype),
    }
