"""Mixture-of-Experts MLP with two dispatch modes: static capacity and
ragged (capacity-free).

Two of the paper's irregular GEMM types appear here as first-class hot spots:

  * the router ``tokens x d_model x num_experts`` is T1 exactly — N = 8..16
    experts is far inside the paper's N <= 96 regime;
  * each expert's (rows x d_model x d_ff/TP) GEMMs are T3 per shard, and the
    backward dW contracts the token dim — the paper's T2 shape per expert.

Dispatch modes (``dispatch=`` / ``ModelConfig.moe_dispatch``):

``"capacity"`` — Switch-style static capacity: routed tokens scatter-pack
into an (E, C, D) buffer (tokens beyond capacity are DROPPED, padding rows
where an expert underflows), expert GEMMs run as padded grouped ftIMM GEMMs.
Shapes are fully static, so this is the jit-friendly oracle the ragged path
is validated against in the undropped regime — but the padding erases the
per-expert irregularity: every expert is priced at C = max rows regardless
of what the router actually did.

``"ragged"`` — megablocks-style capacity-free dispatch: tokens sort by
expert, per-expert counts become a ``group_offsets`` prefix-sum array, and
the expert GEMMs run as *ragged* grouped ftIMM GEMMs (one flat (T*K, D)
operand, per-group weight panels, fused silu(gate)*up epilogue for the
gate/up pair).  No token is ever dropped and no row is padded to a
capacity; the CMR planner prices the actual size distribution
(``plan_ragged_gemm`` — total rows + one boundary tile per expert, not
E x max).

Expert parallelism: when the active ``DistContext`` exposes an expert axis
(``moe_ep_axis``, set by the launchers from ``launch.sharding.expert_axis``)
and the expert count divides it, the ragged path runs its whole MLP through
``core.gemm.ep_ragged_moe`` — the tokens all-to-all to the shard that owns
their expert (keyed by the same ``group_offsets`` prefix sums), the fused
silu(gate)*up and the down projection run on that shard (the d_ff-wide
hidden never crosses the axis), and the inverse exchange returns the
d_model outputs — so each chip holds and streams only its G/num_shards
expert panels.  The placement is priced by the same planner
(``plan_ragged_gemm(..., num_shards=n)`` / ``plan_moe_dispatch``) that picks
the block sizes — strategy x blocking as ONE decision, at mesh scale.

When to prefer which: the planner's ragged estimate beats the capacity
estimate whenever the router is unbalanced (capacity pads every expert to
the max) or when dropping tokens is unacceptable (training quality,
parity evals).  Capacity wins only when distributions are near-uniform AND
the fixed shapes matter more than the ~C/mean padding waste (e.g. frozen
serving graphs where recompilation dominates).  The aux loss is identical
in both modes — it depends only on router probabilities, not dispatch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.dist import current_dist, shard_act
from ..core.gemm import (ep_ragged_moe, grouped_matmul, grouped_swiglu,
                         plan_moe_dispatch, project, ragged_matmul,
                         ragged_swiglu)


def _ep_axis(num_experts: int):
    """The mesh axis carrying the expert dim, when the active DistContext
    exposes one (``launch.sharding.expert_axis``, which already enforces the
    divisibility rule when it knows E) and the expert count divides it —
    else None (single-device / replicated-expert semantics).  The re-check
    here only guards hand-built DistContexts."""
    ctx = current_dist()
    axis = getattr(ctx, "moe_ep_axis", None) if ctx is not None else None
    if not axis:
        return None, None
    from ..core.gemm.distributed import _axis_size
    nc = _axis_size(ctx.mesh, axis)
    if nc <= 1 or num_experts % nc:
        return None, None
    return ctx.mesh, axis


def init_moe_params(key, d_model: int, d_ff: int, num_experts: int,
                    dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    s_in = (2.0 / d_model) ** 0.5
    s_out = (2.0 / d_ff) ** 0.5
    return {
        "router": jax.random.normal(ks[0], (d_model, num_experts), dtype) * s_in,
        "w_gate": jax.random.normal(ks[1], (num_experts, d_model, d_ff), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (num_experts, d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (num_experts, d_ff, d_model), dtype) * s_out,
    }


def capacity(num_tokens: int, num_experts: int, top_k: int,
             capacity_factor: float = 1.25, dtype=jnp.float32) -> int:
    """Per-expert capacity, padded to the *dtype-dependent* sublane multiple.

    The expert GEMM's M dim is the capacity, so it must align to the register
    tile: (8,128) fp32 but (16,128) bf16 — a hardcoded 8 under-pads bf16
    buffers (the same bug class PR 1 fixed in ftimm/ops.py).  Delegates to
    the planner's ``plan_moe_dispatch`` (rows == E x capacity) so the
    runtime dispatch buffer and the roofline's priced rows share ONE
    rounding rule and can never diverge."""
    rows = plan_moe_dispatch(
        num_tokens, num_experts, top_k, 0, 0, dispatch="capacity",
        capacity_factor=capacity_factor,
        elt_bytes=jnp.dtype(dtype).itemsize).rows
    return rows // num_experts


def _router(x: jax.Array, params: dict, num_experts: int, top_k: int):
    """Shared router head: T1 GEMM + top-k gates + Switch-style aux loss."""
    logits = project(x, params["router"].astype(x.dtype),
                     out_dtype=jnp.float32)                      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, top_k)               # (T, K)
    if top_k > 1:
        gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(gate_idx[:, 0], num_experts)
    ce = jnp.mean(one_hot, axis=0)
    aux = num_experts * jnp.sum(me * ce)
    return gate_w, gate_idx, aux


def moe_mlp(
    x: jax.Array,                  # (T, D) flat tokens
    params: dict,
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    compute_dtype=jnp.bfloat16,
    dispatch: str = "capacity",    # "capacity" | "ragged"
    quant: str | None = None,      # core.quant mode for the expert panels
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (T, D), aux_loss scalar).  See module docstring for
    the two dispatch modes; ``capacity_factor`` is ignored by "ragged".

    ``quant`` (a ``core.quant`` mode — "w8"/"w4"/"int8"/...) runs the
    ragged expert GEMMs with quantized per-expert panels: per-expert
    per-channel scales fused at the accumulator flush, straight-through
    backward against the dequantized panels.  Zero-drop int8 experts —
    ragged dispatch only (the capacity path pads and drops; quantizing it
    would conflate two approximations in one parity story)."""
    from ..core import quant as _quant
    qcfg = _quant.resolve(quant)
    if dispatch == "ragged":
        return _moe_mlp_ragged(x, params, num_experts=num_experts,
                               top_k=top_k, compute_dtype=compute_dtype,
                               qcfg=qcfg)
    if dispatch != "capacity":
        raise ValueError(f"unknown moe dispatch: {dispatch}")
    if not qcfg.is_noop:
        raise ValueError("quantized experts require the ragged (zero-drop) "
                         f"dispatch, not {dispatch!r}")
    t, d = x.shape
    e = num_experts
    c = capacity(t, e, top_k, capacity_factor, dtype=compute_dtype)
    xc = x.astype(compute_dtype)

    gate_w, gate_idx, aux = _router(xc, params, e, top_k)

    # Position of each (token, k) within its expert's capacity bucket.
    flat_idx = gate_idx.reshape(-1)                              # (T*K,)
    sel = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)           # (T*K, E)
    pos_in_e = jnp.cumsum(sel, axis=0) - 1                       # rank within expert
    pos = jnp.take_along_axis(pos_in_e, flat_idx[:, None], axis=1)[:, 0]
    keep = pos < c
    slot = jnp.where(keep, flat_idx * c + pos, e * c)            # drop -> OOB

    # Scatter-pack tokens into the (E*C, D) buffer (paper: each "core"
    # receives its private A panel).
    tok_idx = jnp.repeat(jnp.arange(t), top_k)
    buf = jnp.zeros((e * c, d), compute_dtype)
    buf = buf.at[slot].add(xc[tok_idx], mode="drop")
    buf = buf.reshape(e, c, d)
    ctx = current_dist()
    if ctx is not None and ctx.moe_buf_shard:
        # dispatch buffers replicated by default (GSPMD scatter inference);
        # shard capacity over dp — the paper's "each core owns its private
        # A panel" at the MoE level
        buf = shard_act(buf, None, "dp", None)

    # Expert GEMMs (T3 per shard): grouped ftIMM GEMMs (E, C, D) @ (E, D, F)
    # through the CMR planner — the batch dim is the expert index, the
    # per-expert shape is the paper's irregular (capacity x d_model x d_ff);
    # their backward dW is the T2-shaped grouped GEMM, planned the same way.
    # The gate/up pair is ONE fused silu(gate)*up launch (the capacity-mode
    # analogue of the ragged path's fused SwiGLU).
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    h = grouped_swiglu(buf, wg, wu)
    y_buf = grouped_matmul(h, wd).reshape(e * c, d)

    # Gather back and combine with gate weights.
    y_tok = jnp.take(y_buf, jnp.minimum(slot, e * c - 1), axis=0)
    y_tok = y_tok * (keep * gate_w.reshape(-1))[:, None].astype(compute_dtype)
    y = jnp.sum(y_tok.reshape(t, top_k, d), axis=1)
    return y.astype(x.dtype), aux


def _moe_mlp_ragged(
    x: jax.Array,                  # (T, D) flat tokens
    params: dict,
    *,
    num_experts: int,
    top_k: int,
    compute_dtype=jnp.bfloat16,
    qcfg=None,
) -> tuple[jax.Array, jax.Array]:
    """Capacity-free dispatch: sort-by-expert + prefix-sum offsets.

    Every routed (token, k) copy is kept — per-expert row counts become the
    ragged M dims of the grouped ftIMM GEMMs (the irregular shapes the CMR
    planner exists to exploit), and the gate/up pair runs as ONE fused
    silu(gate)*up kernel launch.

    ``qcfg`` (a non-noop ``core.quant.QuantConfig``) swaps the expert GEMMs
    for their quantized ragged forms: gate/up/down each stream int8 (or
    int4/fp8) per-expert panels with the dequant fused at the flush; the
    silu*mul runs elementwise between them (the fused-SwiGLU kernel stays
    full-precision-only — its two panels would need two scale vectors in
    one flush).  The router is NEVER quantized (T1 is tiny and gate
    fidelity is the whole zero-drop story).  Expert-parallel meshes keep
    full-precision panels: the EP pipeline fuses its own exchange."""
    t, d = x.shape
    e = num_experts
    xc = x.astype(compute_dtype)

    gate_w, gate_idx, aux = _router(xc, params, e, top_k)

    # Sort the (T*K,) routed copies by expert id (stable: ties keep token
    # order) and build the per-expert prefix sums — the dynamic group sizes.
    flat_idx = gate_idx.reshape(-1)                              # (T*K,)
    order = jnp.argsort(flat_idx)                                # stable
    tok_sorted = order // top_k                                  # token of slot
    counts = jnp.zeros((e,), jnp.int32).at[flat_idx].add(1)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)]).astype(jnp.int32)

    xs = jnp.take(xc, tok_sorted, axis=0)                        # (T*K, D)

    # Ragged expert GEMMs through the CMR planner: fused gate/up, then down.
    # When the sharding layout exposes an expert axis on the mesh
    # (DistContext.moe_ep_axis), the same GEMMs run expert-parallel: tokens
    # all-to-all to the shard owning their expert (keyed by the very same
    # ``offsets`` prefix sums), G/num_shards panels per shard, inverse
    # exchange on the way back — instead of every chip replicating every
    # expert panel.
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    mesh, ep_axis = _ep_axis(e)
    if ep_axis is not None:
        # Fused EP pipeline: one d_model-wide exchange each way; the
        # (rows, d_ff) hidden stays on the shard owning the expert.
        # (Quantized panels deliberately not routed here: the exchange
        # moves activations, not panels, so quant buys no wire bytes.)
        ys = ep_ragged_moe(xs, wg, wu, wd, offsets, mesh=mesh, axis=ep_axis)
    elif qcfg is not None and not qcfg.is_noop:
        hg = ragged_matmul(xs, wg, offsets, quant=qcfg,
                           out_dtype=jnp.float32)                # (T*K, F)
        hu = ragged_matmul(xs, wu, offsets, quant=qcfg,
                           out_dtype=jnp.float32)
        h = (jax.nn.silu(hg) * hu).astype(compute_dtype)
        ys = ragged_matmul(h, wd, offsets, quant=qcfg)           # (T*K, D)
    else:
        h = ragged_swiglu(xs, wg, wu, offsets)                   # (T*K, F)
        ys = ragged_matmul(h, wd, offsets)                       # (T*K, D)

    # Un-sort and combine with gate weights (every copy kept — no drops).
    gw_sorted = jnp.take(gate_w.reshape(-1), order)
    y = jnp.zeros((t, d), compute_dtype).at[tok_sorted].add(
        ys * gw_sorted[:, None].astype(compute_dtype))
    return y.astype(x.dtype), aux
