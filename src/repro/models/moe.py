"""Mixture-of-Experts MLP with top-k routing and capacity-based dispatch.

Two of the paper's irregular GEMM types appear here as first-class hot spots:

  * the router ``tokens x d_model x num_experts`` is T1 exactly — N = 8..16
    experts is far inside the paper's N <= 96 regime;
  * each expert's (capacity x d_model x d_ff/TP) GEMMs are T3 per shard.

Dispatch is Switch-style with a static per-expert capacity so shapes stay
jit-friendly: tokens beyond capacity are dropped (weight 0), routed tokens
are scatter-packed into an (E, C, D) buffer, expert GEMMs run as grouped
ftIMM GEMMs through the CMR planner (sharded TP on d_ff, optionally EP on
the expert dim), and results gather back with the gate weights applied.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.dist import current_dist, shard_act
from ..core.gemm import grouped_matmul, project


def init_moe_params(key, d_model: int, d_ff: int, num_experts: int,
                    dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    s_in = (2.0 / d_model) ** 0.5
    s_out = (2.0 / d_ff) ** 0.5
    return {
        "router": jax.random.normal(ks[0], (d_model, num_experts), dtype) * s_in,
        "w_gate": jax.random.normal(ks[1], (num_experts, d_model, d_ff), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (num_experts, d_model, d_ff), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (num_experts, d_ff, d_model), dtype) * s_out,
    }


def capacity(num_tokens: int, num_experts: int, top_k: int,
             capacity_factor: float = 1.25) -> int:
    c = int(num_tokens * top_k * capacity_factor / num_experts)
    return max(8, -(-c // 8) * 8)  # pad to sublane multiple


def moe_mlp(
    x: jax.Array,                  # (T, D) flat tokens
    params: dict,
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    compute_dtype=jnp.bfloat16,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output (T, D), aux_loss scalar)."""
    t, d = x.shape
    e = num_experts
    c = capacity(t, e, top_k, capacity_factor)
    xc = x.astype(compute_dtype)

    # Router: the T1 irregular GEMM (T >> D ~ E). fp32 for routing stability.
    logits = project(xc, params["router"].astype(compute_dtype),
                     out_dtype=jnp.float32)                      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, top_k)               # (T, K)
    if top_k > 1:
        gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # Load-balancing aux loss (Switch/Mixtral style).
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(gate_idx[:, 0], e)
    ce = jnp.mean(one_hot, axis=0)
    aux = e * jnp.sum(me * ce)

    # Position of each (token, k) within its expert's capacity bucket.
    flat_idx = gate_idx.reshape(-1)                              # (T*K,)
    sel = jax.nn.one_hot(flat_idx, e, dtype=jnp.int32)           # (T*K, E)
    pos_in_e = jnp.cumsum(sel, axis=0) - 1                       # rank within expert
    pos = jnp.take_along_axis(pos_in_e, flat_idx[:, None], axis=1)[:, 0]
    keep = pos < c
    slot = jnp.where(keep, flat_idx * c + pos, e * c)            # drop -> OOB

    # Scatter-pack tokens into the (E*C, D) buffer (paper: each "core"
    # receives its private A panel).
    tok_idx = jnp.repeat(jnp.arange(t), top_k)
    buf = jnp.zeros((e * c, d), compute_dtype)
    buf = buf.at[slot].add(xc[tok_idx], mode="drop")
    buf = buf.reshape(e, c, d)
    ctx = current_dist()
    if ctx is not None and ctx.moe_buf_shard:
        # dispatch buffers replicated by default (GSPMD scatter inference);
        # shard capacity over dp — the paper's "each core owns its private
        # A panel" at the MoE level
        buf = shard_act(buf, None, "dp", None)

    # Expert GEMMs (T3 per shard): grouped ftIMM GEMMs (E, C, D) @ (E, D, F)
    # through the CMR planner — the batch dim is the expert index, the
    # per-expert shape is the paper's irregular (capacity x d_model x d_ff);
    # their backward dW is the T2-shaped grouped GEMM, planned the same way.
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    h = jax.nn.silu(grouped_matmul(buf, wg)) * grouped_matmul(buf, wu)
    y_buf = grouped_matmul(h, wd).reshape(e * c, d)

    # Gather back and combine with gate weights.
    y_tok = jnp.take(y_buf, jnp.minimum(slot, e * c - 1), axis=0)
    y_tok = y_tok * (keep * gate_w.reshape(-1))[:, None].astype(compute_dtype)
    y = jnp.sum(y_tok.reshape(t, top_k, d), axis=1)
    return y.astype(x.dtype), aux
