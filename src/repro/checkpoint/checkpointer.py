"""Sharded, async, atomic checkpointing with elastic restore.

Layout:
    <dir>/step_000120/
        META.json            — step, leaf paths/shapes/dtypes, config name
        <leaf-path>.npy      — one file per pytree leaf (host-gathered)
        DONE                 — commit marker (write is atomic via tmp+rename)

* async: ``save`` snapshots leaves to host memory, returns immediately and
  writes on a background thread (off the training critical path); ``wait``
  joins.  Failure mid-write never corrupts the previous checkpoint (commit
  marker + directory rename).
* elastic restore: leaves are loaded from disk and ``jax.device_put`` with
  whatever shardings the NEW mesh prescribes — restoring a run saved on a
  (16,16) mesh onto (8,16) (node failure) or (2,16,16) (scale-up) is the
  same code path.  Tested in tests/test_checkpoint.py.
* multi-host note: this writes full leaves from host 0's view (fine for the
  dry-run scale); a per-process shard writer would slot in at ``_to_host``.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict[str, jax.Array]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def name(path):
        parts = []
        for k in path:
            parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
        return _SEP.join(parts)

    return {name(p): v for p, v in flat}


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------ save ------------------------------

    def save(self, step: int, state: dict, *, meta: dict | None = None,
             blocking: bool = False) -> None:
        leaves = _flatten(state)
        host = {k: np.asarray(v) for k, v in leaves.items()}  # snapshot
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host, meta or {}), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: dict, meta: dict) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        index = {}
        for name, arr in host.items():
            fn = name.replace(_SEP, "__") + ".npy"
            np.save(tmp / fn, arr)
            index[name] = {"file": fn, "shape": list(arr.shape),
                           "dtype": str(arr.dtype)}
        with open(tmp / "META.json", "w") as f:
            json.dump({"step": step, "leaves": index, "meta": meta}, f)
        (tmp / "DONE").touch()
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ----------------------------- restore ----------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "DONE").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None,
                shardings=None) -> tuple[int, dict]:
        """Restore into the structure of ``template``; ``shardings`` (same
        pytree structure, optional) re-shards onto the CURRENT mesh —
        elastic resume after mesh changes."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        meta = json.loads((d / "META.json").read_text())
        names = _flatten(template)
        shard_map_ = _flatten(shardings) if shardings is not None else {}

        out = {}
        for name in names:
            info = meta["leaves"][name]
            arr = np.load(d / info["file"])
            sh = shard_map_.get(name)
            out[name] = (jax.device_put(arr, sh) if sh is not None
                         else jax.numpy.asarray(arr))
        # unflatten back into template structure
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)

        def name_of(path):
            return _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                             for k in path)

        leaves = [out[name_of(p)] for p, _ in paths]
        return step, jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
