"""K-means with ftIMM — the paper's own motivating application (§I).

The distance computation ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2 is a
tall-and-skinny GEMM: samples (M ~ 100k) x dims (K = 64) x centroids
(N = 16) — squarely the paper's T1 regime with N <= 96.

    PYTHONPATH=src python examples/kmeans.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.core.gemm import classify, matmul, plan_gemm

M, K, N_CLUSTERS, STEPS = 100_000, 64, 16, 10


def make_blobs(key):
    ck, xk, ak = jax.random.split(key, 3)
    true_centers = jax.random.normal(ck, (N_CLUSTERS, K)) * 5.0
    assign = jax.random.randint(ak, (M,), 0, N_CLUSTERS)
    x = true_centers[assign] + jax.random.normal(xk, (M, K))
    return x, assign


@jax.jit
def kmeans_step(x, centers):
    # T1 GEMM through the ftIMM dispatcher: (M x K) @ (K x N)
    xc = matmul(x, centers.T)                       # (M, N)
    d2 = (jnp.sum(x * x, 1, keepdims=True) - 2 * xc
          + jnp.sum(centers * centers, 1)[None, :])
    assign = jnp.argmin(d2, axis=1)
    one_hot = jax.nn.one_hot(assign, N_CLUSTERS, dtype=x.dtype)
    # centroid update is the T2 shape: (N x M) @ (M x K) -> contraction over
    # the huge sample dim (the paper's K-parallel strategy across chips)
    sums = matmul(one_hot.T, x)
    counts = jnp.maximum(jnp.sum(one_hot, axis=0), 1.0)
    return sums / counts[:, None], assign, jnp.mean(jnp.min(d2, axis=1))


def main():
    key = jax.random.PRNGKey(0)
    x, truth = make_blobs(key)
    print("distance GEMM class:", classify(M, K, N_CLUSTERS).value)
    print("update   GEMM class:", classify(N_CLUSTERS, M, K).value)
    plan = plan_gemm(M, K, N_CLUSTERS)
    print(f"ftIMM plan: blocks=({plan.bm},{plan.bn},{plan.bk}), "
          f"bound={plan.est.bound}")
    centers = x[:N_CLUSTERS]
    for i in range(STEPS):
        centers, assign, inertia = kmeans_step(x, centers)
        print(f"step {i}: inertia={float(inertia):.3f}")
    # clustering quality: most samples should agree with some permutation —
    # just report the final inertia drop
    print("done")


if __name__ == "__main__":
    main()
