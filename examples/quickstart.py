"""Quickstart: the ftIMM public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gemm import (autotune_gemm, classify, clear_plan_store,
                             load_plan_cache, matmul, plan_gemm,
                             plan_distributed, save_plan_cache, tgemm_plan)

key = jax.random.PRNGKey(0)

# 1. The paper's three irregular shapes get classified automatically…
for m, k, n in [(1_000_000, 64, 32), (32, 1_000_000, 32), (20480, 20480, 32)]:
    print(f"({m}, {k}, {n}) -> {classify(m, k, n).value}")

# 2. …and the CMR tuner (dynamic adjusting) picks blocks + strategy per shape.
plan = plan_gemm(1_000_000, 64, 32)
print(f"\nT1 plan: blocks=({plan.bm},{plan.bn},{plan.bk}) "
      f"order={plan.dim_order} bound={plan.est.bound} "
      f"modeled_t={plan.est.t_total:.2e}s")
fixed = tgemm_plan(1_000_000, 64, 32)
print(f"vs fixed TGEMM blocking: {fixed.est.t_total / plan.est.t_total:.1f}x "
      "slower (modeled)")

# 3. Cross-chip strategy selection (paper Alg. 4 vs Alg. 5): ask any
#    planner for a placed plan (num_shards) and read its Placement.
for m, k, n in [(1_000_000, 64, 32), (32, 1_000_000, 32)]:
    p = plan_gemm(m, k, n, num_shards=8)
    assert plan_distributed(m, k, n, 8).strategy == p.placement.strategy
    print(f"8 chips, ({m},{k},{n}): {p.placement.strategy} "
          f"(ici={p.placement.t_collective:.1e}s)")

# 4. matmul() routes every contraction through the planner. On TPU this hits
#    the Pallas ftIMM kernels; on CPU the identically-blocked XLA path.
a = jax.random.normal(key, (4096, 64))
b = jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
out = matmul(a, b)
np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)
print("\nmatmul() matches reference; class =", classify(4096, 64, 32).value)

# 5. The same API differentiates (backward GEMMs are ftIMM-planned too —
#    dW = x.T @ dy is the paper's T2 shape).
g = jax.grad(lambda a, b: jnp.sum(matmul(a, b) ** 2), argnums=1)(a, b)
print("grad through matmul:", g.shape, "finite:", bool(jnp.isfinite(g).all()))

# 6. Auto-tuning workflow (closed loop): the CMR model shortlists candidate
#    tilings, the timing harness MEASURES them on this device, the winner
#    goes to a persistent plan cache the planners consult first, and a
#    calibration pass corrects the model for unmeasured shapes.
#
#    Offline sweep (writes results/plan_cache.json + BENCH_irregular.json):
#        PYTHONPATH=src python -m benchmarks.autotune
#    Serve warmup then loads the cache before compiling anything:
#        PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b-smoke \
#            --plan-cache results/plan_cache.json
import tempfile

res = autotune_gemm(20000, 999, 31, top_k=3, repeats=2)
print(f"\nmeasured search: analytic={res.t_analytic*1e6:.0f}us "
      f"measured={res.t_measured*1e6:.0f}us mode={res.plan.mode}")
assert res.t_measured <= res.t_analytic   # analytic argmin is candidate 0

served = plan_gemm(20000, 999, 31)        # now served from the store
print("plan_gemm mode after tuning:", served.mode)

with tempfile.NamedTemporaryFile(suffix=".json") as f:
    save_plan_cache(f.name)               # persist winners + calibration
    clear_plan_store()
    assert plan_gemm(20000, 999, 31).mode == "analytic"
    print("reloaded entries:", load_plan_cache(f.name),
          "-> mode:", plan_gemm(20000, 999, 31).mode)
