"""Quickstart: the ftIMM public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gemm import (Epilogue, autotune_gemm, classify,
                             clear_plan_store, load_plan_cache, matmul,
                             matmul_swiglu, plan_gemm, plan_distributed,
                             save_plan_cache, tgemm_plan)

key = jax.random.PRNGKey(0)

# 1. The paper's three irregular shapes get classified automatically…
for m, k, n in [(1_000_000, 64, 32), (32, 1_000_000, 32), (20480, 20480, 32)]:
    print(f"({m}, {k}, {n}) -> {classify(m, k, n).value}")

# 2. …and the CMR tuner (dynamic adjusting) picks blocks + strategy per shape.
plan = plan_gemm(1_000_000, 64, 32)
print(f"\nT1 plan: blocks=({plan.bm},{plan.bn},{plan.bk}) "
      f"order={plan.dim_order} bound={plan.est.bound} "
      f"modeled_t={plan.est.t_total:.2e}s")
fixed = tgemm_plan(1_000_000, 64, 32)
print(f"vs fixed TGEMM blocking: {fixed.est.t_total / plan.est.t_total:.1f}x "
      "slower (modeled)")

# 3. Cross-chip strategy selection (paper Alg. 4 vs Alg. 5): ask any
#    planner for a placed plan (num_shards) and read its Placement.
for m, k, n in [(1_000_000, 64, 32), (32, 1_000_000, 32)]:
    p = plan_gemm(m, k, n, num_shards=8)
    assert plan_distributed(m, k, n, 8).strategy == p.placement.strategy
    print(f"8 chips, ({m},{k},{n}): {p.placement.strategy} "
          f"(ici={p.placement.t_collective:.1e}s)")

# 4. matmul() routes every contraction through the planner. On TPU this hits
#    the Pallas ftIMM kernels; on CPU the identically-blocked XLA path.
a = jax.random.normal(key, (4096, 64))
b = jax.random.normal(jax.random.fold_in(key, 1), (64, 32))
out = matmul(a, b)
np.testing.assert_allclose(out, a @ b, rtol=1e-4, atol=1e-4)
print("\nmatmul() matches reference; class =", classify(4096, 64, 32).value)

# 5. The same API differentiates (backward GEMMs are ftIMM-planned too —
#    dW = x.T @ dy is the paper's T2 shape).
g = jax.grad(lambda a, b: jnp.sum(matmul(a, b) ** 2), argnums=1)(a, b)
print("grad through matmul:", g.shape, "finite:", bool(jnp.isfinite(g).all()))

# 6. Auto-tuning workflow (closed loop): the CMR model shortlists candidate
#    tilings, the timing harness MEASURES them on this device, the winner
#    goes to a persistent plan cache the planners consult first, and a
#    calibration pass corrects the model for unmeasured shapes.
#
#    Offline sweep (writes results/plan_cache.json + BENCH_irregular.json):
#        PYTHONPATH=src python -m benchmarks.autotune
#    Serve warmup then loads the cache before compiling anything:
#        PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b-smoke \
#            --plan-cache results/plan_cache.json
import tempfile

res = autotune_gemm(20000, 999, 31, top_k=3, repeats=2)
print(f"\nmeasured search: analytic={res.t_analytic*1e6:.0f}us "
      f"measured={res.t_measured*1e6:.0f}us mode={res.plan.mode}")
assert res.t_measured <= res.t_analytic   # analytic argmin is candidate 0

served = plan_gemm(20000, 999, 31)        # now served from the store
print("plan_gemm mode after tuning:", served.mode)

with tempfile.NamedTemporaryFile(suffix=".json") as f:
    save_plan_cache(f.name)               # persist winners + calibration
    clear_plan_store()
    assert plan_gemm(20000, 999, 31).mode == "analytic"
    print("reloaded entries:", load_plan_cache(f.name),
          "-> mode:", plan_gemm(20000, 999, 31).mode)

# 7. Fused epilogues + zero-copy edge tiles: the elementwise tail
#    (bias / activation / residual / scale) rides the GEMM's fp32
#    accumulator flush instead of separate passes over the output, and
#    non-block-multiple shapes run UNPADDED (in-kernel edge-tile masks —
#    note the deliberately awkward 4096+1 x 999 x 31 shape: no pad copy in,
#    no slice out).  Everything differentiates.
x = jax.random.normal(key, (4097, 999))
w = jax.random.normal(jax.random.fold_in(key, 2), (999, 31))
bias = jax.random.normal(jax.random.fold_in(key, 3), (31,))
h = jax.random.normal(jax.random.fold_in(key, 4), (4097, 31))
y = matmul(x, w, epilogue=Epilogue(bias=True, activation="gelu",
                                   residual=True),
           bias=bias, residual=h)
np.testing.assert_allclose(
    y, jax.nn.gelu((x @ w) + bias) + h, rtol=1e-3, atol=1e-3)
print("\nfused epilogue matches reference on the unpadded path:", y.shape)

# The dense MLP front half is ONE launch: silu(x@Wg) * (x@Wu).
wg = jax.random.normal(jax.random.fold_in(key, 5), (999, 64))
wu = jax.random.normal(jax.random.fold_in(key, 6), (999, 64))
hh = matmul_swiglu(x, wg, wu)
np.testing.assert_allclose(hh, jax.nn.silu(x @ wg) * (x @ wu),
                           rtol=1e-3, atol=1e-3)
plan = plan_gemm(4097, 999, 31, epi_ops=2)   # fusion is a planned decision
print(f"plan for the fused layer: edge={plan.edge} fuse={plan.fuse}")

# 8. Static verification: every plan can be PROVEN safe before it runs —
#    VMEM budget, block clamping/alignment, schedule legality, and (for
#    dense/batched) a symbolic store-coverage/write-race proof over the
#    kernel's real BlockSpec index maps.  No device time, no execution.
from repro.analysis import check_plan, errors

assert not errors(check_plan("dense", (4097, 999, 31), plan,
                             coverage=True))
print("\nstatic contracts hold for the fused-layer plan")

import dataclasses
bad = dataclasses.replace(plan, bk=4096)     # unclamped vs K=999
codes = [v.code for v in errors(check_plan("dense", (4097, 999, 31), bad))]
print("corrupt plan flagged:", codes)        # ['unclamped_block', ...]

# Belt-and-braces at dispatch: REPRO_VERIFY=1 asserts the contracts on
# every planned launch (raises ContractError instead of running a bad
# plan), and plan-cache loading quarantines violating records.  The full
# ratchet: PYTHONPATH=src python -m repro.analysis.sweep

# 9. Quantized irregular GEMMs (the dtype axis): quant= quantizes the
#    weight panel in-trace — per-channel int8 (w8), nibble-packed int4
#    (w4), dynamic full int8, or fp8 — with the dequant scale vector fused
#    at the accumulator flush, and a straight-through backward against the
#    dequantized panel.  The error is bounded analytically, not vibes.
from repro.core import quant

yq = matmul(x, w, quant="w8", out_dtype=jnp.float32)
bound = quant.dot_error_bound(
    x.shape[1], float(jnp.abs(x).max()), float(jnp.abs(w).max()),
    0.0, float(quant.quantize_weights(w, quant.QuantConfig("w8"))[1].max()))
err = float(jnp.abs(yq - x @ w).max())
print(f"\nw8 matmul: max|err|={err:.3e} <= bound {bound:.3e}:",
      err <= bound)

# Pre-quantized weights (decode serving holds them int8 at rest) use the
# manual spelling: the scale-vector epilogue on a mixed-dtype GEMM.  The
# planner keys these separately (the |bb1 dtype axis of the plan cache).
wq, s = quant.quantize_weights(w, quant.QuantConfig("w8"))
y2 = matmul(x, wq, epilogue=Epilogue(scale_vec=True), scale=s,
            out_dtype=jnp.float32)
np.testing.assert_allclose(y2, yq, rtol=1e-5, atol=1e-5)
print("pre-quantized spelling agrees; decode bench: "
      "PYTHONPATH=src python -m benchmarks.quant")
# Zero-drop quantized MoE experts: moe_mlp(..., dispatch="ragged",
# quant="w8") — or any registry arch as "<arch>-w8" / "-int8".

# 10. Chaos-tested graceful degradation: every failure mode is a seeded,
#     replayable event (runtime.chaos), and the dispatch ladder degrades
#     pallas -> XLA / fused -> unfused / EP ring -> gather -> single-device
#     instead of crashing.  Telemetry counts every degraded serving.
import warnings
from repro.core.gemm import plan_mode_stats
from repro.runtime import chaos

with chaos.chaos(chaos.FaultPlan([chaos.Fault("kernel", at=0)])):
    with warnings.catch_warnings():         # the rung warns once
        warnings.simplefilter("ignore", RuntimeWarning)
        y_deg = matmul(x, w, backend="pallas_interpret")  # kernel "fails"
np.testing.assert_allclose(y_deg, x @ w, rtol=1e-5, atol=1e-5)
print("\ninjected kernel fault served by the XLA rung:",
      plan_mode_stats()["degraded"])        # {'dense:pallas->xla': 1}
# Subprocess/CI spelling: REPRO_CHAOS="kernel@0;shard_loss@3:chips=4".
# Elastic training (shard loss -> shrink mesh -> re-plan -> restore ->
# deterministic replay) lives in repro.runtime.elastic.ElasticRunner;
# serve containment (retry/quarantine/deadlines) in repro.serve.engine.

# 11. Overload-safe serving: length-bucketed batch prefill (one compiled
#     prefill per bucket, plan-store warmed for exactly those GEMM
#     signatures at construction), paged KV with an exhaustion-safe
#     allocator (page pressure preempts the lowest-priority request and
#     re-prefills it later — never OOM, never a hang), and CMR-priced
#     admission control: once calibrated, a deadline the projected
#     completion cannot meet is rejected with a typed Overloaded at
#     submit() instead of silently eating the queue.
from repro.configs import get_config
from repro.models.model import init_params
from repro.serve import Overloaded, Request, ServeEngine

cfg = get_config("qwen3-1.7b-smoke")
eng = ServeEngine(cfg, init_params(cfg, key), batch_slots=2, max_len=64)
print(f"\nserve: buckets={list(eng.buckets)} "
      f"warmed={eng.cost.snapshot()['warmed_signatures']} GEMM signatures, "
      f"pool={eng.alloc.total} pages x {eng.page_size} rows")
prompt = np.arange(2, 10, dtype=np.int32)
reqs = [Request(rid=i, prompt=prompt, max_new_tokens=4) for i in range(4)]
eng.run(reqs)                               # calibrates the cost model
assert eng.cost.calibrated()
try:
    eng.submit(Request(rid=9, prompt=prompt, max_new_tokens=40,
                       deadline_s=1e-9))    # projected > deadline
except Overloaded as e:
    print(f"admission control: {e} (projected {e.projected_s:.3f}s)")
# Overload benchmark (0.5x/1x/2x of measured capacity, shed-rate + p99):
#     PYTHONPATH=src python -m benchmarks.serve
