"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production substrate (config -> sharding-ready trainer -> synthetic
pipeline -> async checkpoints), on whatever devices are available.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--ckpt /tmp/ck]
"""
import argparse
import dataclasses
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.optim.adamw import OptConfig
from repro.train.trainer import Trainer


def build_100m():
    """qwen3-family stack scaled to ~100M params."""
    base = get_config("qwen3-1.7b")
    return dataclasses.replace(
        base, name="qwen3-100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        qk_norm=True, remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm_ckpt")
    args = ap.parse_args()

    cfg = build_100m()
    print(f"model: {cfg.name}, params ~{cfg.param_count()/1e6:.0f}M")
    shape = ShapeConfig("ex", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    opt = OptConfig(lr=6e-4, warmup_steps=max(10, args.steps // 20),
                    total_steps=args.steps)
    trainer = Trainer(cfg, shape, opt, ckpt_dir=args.ckpt, ckpt_every=100,
                      log_every=10)
    trainer.run(args.steps)
    first = trainer.metrics_log[0]["loss"]
    last = trainer.metrics_log[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
