"""Serve a small model with batched requests through the slot engine
(continuous batching + greedy/temperature sampling).

    PYTHONPATH=src python examples/serve_lm.py [--requests 6]
"""
import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b-smoke")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=args.slots, max_len=64)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, 16).astype(np.int32),
                    max_new_tokens=args.max_new,
                    temperature=0.7 if i % 2 else 0.0)
            for i in range(args.requests)]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    for r in reqs:
        print(f"req {r.rid} (T={r.temperature}): {r.out_tokens}")
    print(f"{total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s across {args.slots} slots)")


if __name__ == "__main__":
    main()
